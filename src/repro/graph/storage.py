"""Partition-aware graph storage: the engine's single edge-storage layer.

This module absorbs the edge-buffer plumbing that used to be spread
across ``graph/structures.EdgeStore`` and the per-backend upload paths in
``core/backend.py``. Two classes:

  * :class:`EdgeStore` — paired host/device edge buffers with capacity
    headroom and staged in-place mutation (moved verbatim from
    ``graph/structures.py``, which still re-exports it), now extended
    with a checkpoint seam (``state_dict``/``extra_state``/
    ``load_state``/``from_state``) and a spill seam (``drop_device``/
    ``ensure_device``).

  * :class:`GraphStore` — the partition-aware subclass implementing the
    paper's linear-local-space regime (Ceccarello et al., PAPERS.md):
    edges are relabeled ONCE through the ``graph/partition.py``
    cluster-locality permutation so whole clusters land on one shard,
    split into per-shard slabs (destination-owned, the same owner rule as
    ``core/distributed.shard_graph``), with an explicit halo index — the
    boundary source nodes whose plane rows must be exchanged between
    supersteps. Slabs can be held compressed at rest via the lossless
    ``runtime/compression.pack_i32`` codec and are decompressed on demand
    in the grow path.

Halo-exchange consistency contract: for every shard ``p`` and every edge
``(u -> v)`` relaxed by ``p`` (i.e. ``owner(v) == p``), the source plane
row ``u`` is either owner-local (``owner(u) == p``) or listed in
``halo_index()[p]`` — and the static per-pair plan derived from that
index is exactly what ``core/distributed.DistributedEngine(comm="halo")``
ships, so halo exchange and the full-plane all-gather are byte-identical
in results while moving strictly fewer bytes whenever any row is not a
boundary row.

Wire-byte accounting is static: the halo plan is fixed at build time, so
``halo_bytes_per_superstep()`` (and the all-gather baseline
``fullplane_bytes_per_superstep()``) are exact numbers multiplied by the
measured superstep count — no extra host sync is ever spent on metering.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.common import get_logger, next_multiple
from repro.graph.partition import apply_partition, cluster_partition
from repro.graph.structures import (
    EDGE_STORE_BUCKET,
    MAX_WEIGHT,
    EdgeList,
)
from repro.runtime.compression import PackedI32, pack_i32, unpack_i32

log = get_logger("repro.storage")

# six int32 planes (d, c, pathw, final_c, final_pathw, offset is folded
# into d; covered/is_center ride as int32 in the relay) exchanged per
# node row per superstep — matches core/distributed's relay layout
PLANE_ROW_BYTES = 6 * 4


class EdgeStore:
    """Paired host/device edge buffers with capacity headroom, built for
    in-place mutation: each directed ``(u, v)`` key owns at most one slot,
    unused slots are inert self-loops (``0 -> 0, w = 1`` — the same padding
    convention as pooled sessions, invisible to relaxation, SSSP and the
    quotient pass), and freed slots are recycled before the arrays grow.

    Mutations stage on the host (``set_edge`` / ``delete_edge``) and land on
    the device in ONE scatter round per plane per ``flush()`` — no full
    re-upload unless the capacity actually grows (``uploads`` counts those;
    growth doubles, so re-uploads amortize to O(log E) over any update
    stream). Duplicate input edges are min-coalesced at build time
    (``EdgeList.coalesce`` semantics) so a key's slot always carries its
    effective minimum weight — the contract incremental insertion relies on.

    ``min_capacity`` floors the initial capacity (before bucketing) so
    pooled sessions can pin every same-sized graph to one capacity bucket
    and share the engine's shape-keyed jit cache.
    """

    def __init__(self, edges: EdgeList, *, headroom: float = 1.5,
                 bucket: int = EDGE_STORE_BUCKET, min_capacity: int = 0):
        if headroom < 1.0:
            raise ValueError(f"headroom must be >= 1.0, got {headroom}")
        self.n_nodes = int(edges.n_nodes)
        self.bucket = int(bucket)
        e = edges.n_edges
        cap = next_multiple(
            max(int(e * headroom), e, int(min_capacity), 1), self.bucket)
        self.h_src = np.zeros(cap, np.int32)
        self.h_dst = np.zeros(cap, np.int32)
        self.h_weight = np.ones(cap, np.int32)
        self.valid = np.zeros(cap, bool)
        self.slot_of: Dict[Tuple[int, int], int] = {}
        # min-coalesce duplicates and drop self-loops through THE
        # property-tested EdgeList helpers (one copy of the contract);
        # losers become free slots
        clean = edges.remove_self_loops().coalesce()
        k = clean.n_edges
        if k:
            self.h_src[:k] = clean.src
            self.h_dst[:k] = clean.dst
            self.h_weight[:k] = clean.weight
            self.valid[:k] = True
            self.slot_of = {
                (int(u), int(v)): s
                for s, (u, v) in enumerate(zip(clean.src, clean.dst))}
        self.free: List[int] = list(range(int(self.valid.sum()), cap))[::-1]
        self._pending: Dict[int, Tuple[int, int, int]] = {}
        self.src = jnp.asarray(self.h_src)
        self.dst = jnp.asarray(self.h_dst)
        self.weight = jnp.asarray(self.h_weight)
        self.uploads = 1   # full-array device placements (build + growth)
        self.scatters = 0  # in-place scatter rounds (one per flushed batch)

    # -- introspection ------------------------------------------------------

    @property
    def capacity(self) -> int:
        return len(self.h_src)

    @property
    def n_edges(self) -> int:
        return int(self.valid.sum())

    def lookup(self, u: int, v: int) -> Optional[int]:
        """Current weight of directed edge (u, v), or None if absent."""
        s = self.slot_of.get((u, v))
        return int(self.h_weight[s]) if s is not None else None

    def edge_list(self) -> EdgeList:
        """Host materialization of the REAL (valid) edges."""
        m = self.valid
        return EdgeList(self.n_nodes, self.h_src[m].copy(),
                        self.h_dst[m].copy(), self.h_weight[m].copy())

    # -- staged mutation ----------------------------------------------------

    def _check_endpoint(self, u: int, v: int) -> None:
        n = self.n_nodes
        if not (0 <= u < n and 0 <= v < n):
            raise ValueError(f"edge ({u}, {v}) out of range for {n} nodes")

    def set_edge(self, u: int, v: int, w: int) -> None:
        """Stage insert-or-reweight of directed edge (u, v) to weight w."""
        self._check_endpoint(u, v)
        if not (1 <= w <= int(MAX_WEIGHT)):
            raise ValueError(f"edge weights must be in [1, 2^30), got {w}")
        s = self.slot_of.get((u, v))
        if s is None:
            if not self.free:
                self._grow(self.capacity + 1)
            s = self.free.pop()
            self.slot_of[(u, v)] = s
            self.valid[s] = True
        self.h_src[s], self.h_dst[s], self.h_weight[s] = u, v, w
        self._pending[s] = (u, v, w)

    def delete_edge(self, u: int, v: int) -> None:
        """Stage removal of directed edge (u, v): the slot reverts to an
        inert self-loop and is recycled for future insertions."""
        s = self.slot_of.pop((u, v), None)
        if s is None:
            raise ValueError(f"cannot delete missing edge ({u}, {v})")
        self.valid[s] = False
        self.free.append(s)
        self.h_src[s], self.h_dst[s], self.h_weight[s] = 0, 0, 1
        self._pending[s] = (0, 0, 1)

    def _grow(self, min_capacity: int) -> None:
        cap = next_multiple(max(min_capacity, 2 * self.capacity), self.bucket)
        pad = cap - self.capacity
        self.free = list(range(self.capacity, cap))[::-1] + self.free
        self.h_src = np.concatenate([self.h_src, np.zeros(pad, np.int32)])
        self.h_dst = np.concatenate([self.h_dst, np.zeros(pad, np.int32)])
        self.h_weight = np.concatenate([self.h_weight, np.ones(pad, np.int32)])
        self.valid = np.concatenate([self.valid, np.zeros(pad, bool)])

    def flush(self) -> bool:
        """Land staged mutations on device. Returns True when the device
        arrays were REPLACED (capacity growth -> full upload, so callers
        must rebind any views); False means one in-place scatter round."""
        self.ensure_device()
        grew = len(self.h_src) != int(self.src.shape[0])
        if grew:
            self.src = jnp.asarray(self.h_src)
            self.dst = jnp.asarray(self.h_dst)
            self.weight = jnp.asarray(self.h_weight)
            self.uploads += 1
        elif self._pending:
            slots = np.fromiter(self._pending, np.int32,
                                count=len(self._pending))
            svw = np.array(list(self._pending.values()), np.int32)
            self.src = self.src.at[slots].set(svw[:, 0])
            self.dst = self.dst.at[slots].set(svw[:, 1])
            self.weight = self.weight.at[slots].set(svw[:, 2])
            self.scatters += 1
        self._pending.clear()
        return grew

    # -- spill seam ---------------------------------------------------------

    def drop_device(self) -> None:
        """Release the device arrays (session spill path). The host
        mirrors stay the source of truth; ``ensure_device()`` re-uploads
        before the next bind."""
        self.src = self.dst = self.weight = None

    def ensure_device(self) -> None:
        """Re-upload after :meth:`drop_device`; no-op when resident."""
        if self.src is None:
            self.src = jnp.asarray(self.h_src)
            self.dst = jnp.asarray(self.h_dst)
            self.weight = jnp.asarray(self.h_weight)
            self.uploads += 1

    # -- checkpoint seam ----------------------------------------------------

    def state_dict(self) -> Dict[str, np.ndarray]:
        """Host-mirrored buffers as a flat tree for ``checkpoint.save``.

        The free-slot recycling list rides along WITH ITS ORDER (slots
        recycle LIFO, so replaying the same update stream after restore
        lands edges in the same slots — the byte-identical-resume
        contract extends to dynamic updates), and the arrays are saved at
        full capacity so headroom survives restore. ``slot_of`` is
        derivable from ``(valid, h_src, h_dst)`` and is rebuilt on load.
        """
        return {
            "h_src": self.h_src.copy(),
            "h_dst": self.h_dst.copy(),
            "h_weight": self.h_weight.copy(),
            "valid": self.valid.copy(),
            "free": np.asarray(self.free, np.int64),
        }

    def extra_state(self) -> Dict[str, Any]:
        """JSON-able scalars for the checkpoint manifest."""
        return {"kind": type(self).__name__, "n_nodes": self.n_nodes,
                "bucket": self.bucket, "capacity": self.capacity}

    def load_state(self, tree: Dict[str, np.ndarray],
                   extra: Dict[str, Any]) -> None:
        """Restore host+device buffers in place from a ``state_dict``
        round-trip. Staged-but-unflushed mutations are discarded — the
        checkpoint is the durable truth."""
        if int(extra["n_nodes"]) != self.n_nodes:
            raise ValueError(
                f"checkpoint is for n_nodes={extra['n_nodes']}, "
                f"store has n_nodes={self.n_nodes}")
        self.bucket = int(extra.get("bucket", self.bucket))
        self.h_src = np.asarray(tree["h_src"], np.int32).copy()
        self.h_dst = np.asarray(tree["h_dst"], np.int32).copy()
        self.h_weight = np.asarray(tree["h_weight"], np.int32).copy()
        self.valid = np.asarray(tree["valid"], bool).copy()
        self.free = [int(s) for s in np.asarray(tree["free"])]
        self.slot_of = {
            (int(self.h_src[s]), int(self.h_dst[s])): s
            for s in np.flatnonzero(self.valid)}
        self._pending = {}
        self.src = jnp.asarray(self.h_src)
        self.dst = jnp.asarray(self.h_dst)
        self.weight = jnp.asarray(self.h_weight)
        self.uploads += 1

    @classmethod
    def from_state(cls, tree: Dict[str, np.ndarray],
                   extra: Dict[str, Any]) -> "EdgeStore":
        """Rebuild a store from a checkpoint without the original edges."""
        store = cls.__new__(cls)
        store.n_nodes = int(extra["n_nodes"])
        store.bucket = int(extra.get("bucket", EDGE_STORE_BUCKET))
        store.uploads = 0
        store.scatters = 0
        store._init_subclass_blank(extra)
        store.load_state(tree, extra)
        return store

    def _init_subclass_blank(self, extra: Dict[str, Any]) -> None:
        """Hook for subclasses to set their extra attributes before
        ``load_state`` runs during :meth:`from_state`."""


# ---------------------------------------------------------------------------
# partition-aware sharded storage
# ---------------------------------------------------------------------------


@dataclass
class EdgeSlab:
    """One shard's resident edge columns. ``src`` carries GLOBAL
    (relabeled) node ids; ``dst`` is owner-local only after device
    placement — here both are global for host-side streaming. Columns are
    either raw int32 arrays or :class:`PackedI32` when compressed."""

    shard: int
    n_edges: int
    src: Union[np.ndarray, PackedI32]
    dst: Union[np.ndarray, PackedI32]
    weight: Union[np.ndarray, PackedI32]
    compressed: bool

    @property
    def nbytes(self) -> int:
        return sum(int(c.nbytes) for c in (self.src, self.dst, self.weight))


class GraphStore(EdgeStore):
    """Partition-aware edge storage: EdgeStore semantics plus per-shard
    slabs, an explicit halo index and optional compressed residency.

    Construction relabels the graph ONCE through the cluster-locality
    permutation (``graph/partition.cluster_partition``) when pilot
    ``centers`` are given — whole clusters land on one shard, so most
    plane-row reads are shard-local and the halo (the rows that must
    travel) stays small. Every consumer of the store sees the RELABELED
    node ids; ``perm`` (new -> old) / ``inv_perm`` (old -> new) map back.
    Without centers the split is the contiguous range partition (the
    baseline ``cut_fraction`` is measured against).

    Slabs are destination-owned (``owner(v) = v // nodes_per_shard``),
    matching ``core/distributed.shard_graph`` exactly: a GraphStore with
    ``n_shards == mesh devices`` hands its layout to
    ``DistributedEngine`` via :meth:`sharded_graph` with no re-sharding.

    With ``compress=True``, slab columns rest as lossless
    ``pack_i32`` streams (delta + zig-zag + minimal width — exact int32
    round-trip) and are decompressed on demand by :meth:`slab`;
    ``decompressions`` counts the on-demand unpacks the grow path paid.
    """

    def __init__(self, edges: EdgeList, *, n_shards: int = 1,
                 centers: Optional[np.ndarray] = None,
                 compress: bool = False, headroom: float = 1.5,
                 bucket: int = EDGE_STORE_BUCKET, min_capacity: int = 0):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.compress = bool(compress)
        self.perm: Optional[np.ndarray] = None
        self.inv_perm: Optional[np.ndarray] = None
        if centers is not None and self.n_shards > 1 and edges.n_nodes:
            perm = cluster_partition(np.asarray(centers), self.n_shards)
            edges, inv = apply_partition(edges, perm)
            self.perm = np.asarray(perm, np.int32)
            self.inv_perm = np.asarray(inv, np.int32)
        super().__init__(edges, headroom=headroom, bucket=bucket,
                         min_capacity=min_capacity)
        self.decompressions = 0
        self._slabs: Optional[List[EdgeSlab]] = None
        self._halo: Optional[Dict[int, np.ndarray]] = None
        self._halo_k: Optional[int] = None

    # -- shard geometry -----------------------------------------------------

    @property
    def n_pad(self) -> int:
        """Node count padded to a multiple of ``n_shards`` (the same
        padded id space ``core/distributed.shard_graph`` uses)."""
        return next_multiple(self.n_nodes, self.n_shards)

    @property
    def nodes_per_shard(self) -> int:
        return self.n_pad // self.n_shards if self.n_shards else 0

    def shard_of(self, node):
        """Owner shard of a (relabeled) node id — destination rule."""
        return node // max(self.nodes_per_shard, 1)

    # -- mutation invalidates the derived layout ----------------------------

    def _invalidate(self) -> None:
        self._slabs = None
        self._halo = None
        self._halo_k = None

    def set_edge(self, u: int, v: int, w: int) -> None:
        super().set_edge(u, v, w)
        self._invalidate()

    def delete_edge(self, u: int, v: int) -> None:
        super().delete_edge(u, v)
        self._invalidate()

    def flush(self) -> bool:
        grew = super().flush()
        if grew:
            self._invalidate()
        return grew

    # -- slabs and halo index -----------------------------------------------

    def _build_layout(self) -> None:
        e = self.edge_list().sorted_by_dst()
        q = max(self.nodes_per_shard, 1)
        owner = e.dst // q
        slabs: List[EdgeSlab] = []
        halo: Dict[int, np.ndarray] = {}
        k_max = 0
        for p in range(self.n_shards):
            m = owner == p
            src, dst, w = e.src[m], e.dst[m], e.weight[m]
            remote = np.unique(src[src // q != p]).astype(np.int32)
            halo[p] = remote
            if remote.size:
                # padded static-plan width: K is the LARGEST per-pair
                # unique-source count (every pair ships K rows, matching
                # DistributedEngine's rectangular all_to_all tables)
                per_pair = np.bincount(remote // q, minlength=self.n_shards)
                k_max = max(k_max, int(per_pair.max()))
            if self.compress:
                slabs.append(EdgeSlab(p, int(src.size), pack_i32(src),
                                      pack_i32(dst), pack_i32(w), True))
            else:
                slabs.append(EdgeSlab(p, int(src.size), src, dst, w, False))
        self._slabs = slabs
        self._halo = halo
        self._halo_k = k_max

    def slabs(self) -> List[EdgeSlab]:
        """The per-shard slabs (built lazily, possibly compressed)."""
        if self._slabs is None:
            self._build_layout()
        return self._slabs

    def slab(self, p: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Materialized ``(src, dst, weight)`` of shard ``p`` — the grow
        path's on-demand decompression point for compressed residency."""
        s = self.slabs()[p]
        if not s.compressed:
            return s.src, s.dst, s.weight
        self.decompressions += 1
        return unpack_i32(s.src), unpack_i32(s.dst), unpack_i32(s.weight)

    def halo_index(self) -> Dict[int, np.ndarray]:
        """shard -> sorted unique REMOTE source node ids whose plane rows
        must be exchanged before that shard can relax its slab. Every
        source a shard reads is either owner-local or listed here — the
        halo-exchange consistency contract."""
        if self._halo is None:
            self._build_layout()
        return self._halo

    def halo_rows(self) -> int:
        """Total boundary rows across shards (unpadded halo size)."""
        return sum(int(v.size) for v in self.halo_index().values())

    def halo_k(self) -> int:
        """Static-plan table width: max unique sources any (owner, reader)
        shard pair exchanges. Matches ``ShardedGraph.halo_k``."""
        if self._halo_k is None:
            self._build_layout()
        return self._halo_k

    # -- wire-byte accounting (static plan x measured supersteps) -----------

    def halo_bytes_per_superstep(self) -> int:
        """Collective plane-row bytes ONE superstep moves under the
        static halo all_to_all plan: every device ships the rectangular
        ``[n_shards, K]`` table (6 int32 planes per row). Exact and
        sync-free — the plan is fixed at build time."""
        p = self.n_shards
        if p <= 1:
            return 0
        return PLANE_ROW_BYTES * p * p * self.halo_k()

    def fullplane_bytes_per_superstep(self) -> int:
        """The full-plane all-gather baseline: every device receives all
        ``n_pad`` rows of the six planes each superstep."""
        p = self.n_shards
        if p <= 1:
            return 0
        return PLANE_ROW_BYTES * self.n_pad * p

    def resident_bytes(self) -> int:
        """Bytes the slabs occupy at rest (compressed when enabled)."""
        return sum(s.nbytes for s in self.slabs())

    def raw_bytes(self) -> int:
        """Uncompressed slab footprint (3 int32 columns per edge)."""
        return sum(3 * 4 * s.n_edges for s in self.slabs())

    # -- sharded-execution handoff ------------------------------------------

    def sharded_graph(self, build_halo: bool = True):
        """The device layout for ``core/distributed.DistributedEngine``,
        built from THIS store's (relabeled) edges — the single
        construction path for sharded execution
        (``core.backend.make_backend(store, "sharded")``).

        Under compressed residency the slab columns are materialized
        through :meth:`slab` — the grow path's on-demand decompression
        point — instead of the flat host mirror."""
        from repro.core.distributed import shard_graph  # lazy: no core dep at import

        if self.compress:
            # slabs are dst-sorted per shard and shards are ordered by
            # owner (= dst // q), so concatenation is globally dst-sorted
            cols = [self.slab(p) for p in range(self.n_shards)]
            e = EdgeList(self.n_nodes,
                         np.concatenate([c[0] for c in cols]),
                         np.concatenate([c[1] for c in cols]),
                         np.concatenate([c[2] for c in cols]))
        else:
            e = self.edge_list()
        return shard_graph(e, self.n_shards, build_halo=build_halo)

    # -- checkpoint seam ----------------------------------------------------

    def state_dict(self) -> Dict[str, np.ndarray]:
        tree = super().state_dict()
        # always present (identity when unpartitioned) so checkpoint
        # trees keep one structure regardless of how the reader's fresh
        # store was built
        tree["perm"] = (self.perm.copy() if self.perm is not None
                        else np.arange(self.n_nodes, dtype=np.int32))
        return tree

    def extra_state(self) -> Dict[str, Any]:
        d = super().extra_state()
        d.update(n_shards=self.n_shards, compress=self.compress,
                 partitioned=self.perm is not None)
        return d

    def load_state(self, tree: Dict[str, np.ndarray],
                   extra: Dict[str, Any]) -> None:
        if "n_shards" in extra and int(extra["n_shards"]) != self.n_shards:
            raise ValueError(
                f"checkpoint has n_shards={extra['n_shards']}, "
                f"store has n_shards={self.n_shards}")
        super().load_state(tree, extra)
        if extra.get("partitioned"):
            self.perm = np.asarray(tree["perm"], np.int32).copy()
            inv = np.empty_like(self.perm)
            inv[self.perm] = np.arange(len(self.perm), dtype=np.int32)
            self.inv_perm = inv
        self._invalidate()

    def _init_subclass_blank(self, extra: Dict[str, Any]) -> None:
        self.n_shards = int(extra.get("n_shards", 1))
        self.compress = bool(extra.get("compress", False))
        self.perm = None
        self.inv_perm = None
        self.decompressions = 0
        self._slabs = None
        self._halo = None
        self._halo_k = None
